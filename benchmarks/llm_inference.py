"""llama.cpp-style LLM inference (paper Fig. 9): paged vs dense engines, and
prefix-cached vs re-prefill admission.

The paper reports 70B llama.cpp decode throughput on the Grace CPU.  This
harness serves a reduced model through the continuous-batching engine:

* **paged vs dense** — once with the slot-granular dense cache and once with
  the paged block-pool cache at the **same cache-byte budget**: decode
  tokens/s, blocks in use, achievable concurrency under each layout.
* **shared-system-prompt** — the interactive multi-tenant workload the
  machine's Jupyter/web front-ends serve: every request carries the same
  system prompt plus a short unique tail.  The prefix-cached engine
  prefills the shared blocks once and admits every later request for the
  price of its suffix; the A/B reports mean TTFT and *prefill tokens
  actually computed*, cached vs uncached (the cached side must compute
  >= 2x fewer).
* **tensor-parallel** (``--tp N``) — the same engine spanning N devices of
  a ``(data=1, model=N)`` mesh, the paper's 4-way Grace-Hopper node in
  miniature: params and paged K/V pools shard over the model axis while
  the allocator / prefix index / block tables stay replicated host state.
  The A/B asserts greedy TP=N output is **token-identical** to TP=1 and
  reports global vs per-device cache bytes (the KV-capacity win of
  spanning the node: per-device bytes drop ~1/N, so the same HBM holds an
  N-times larger logical pool).  Results go to
  ``benchmarks/results/llm_inference_tp.json``; on CPU force devices with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
* **speculative decode** — the repetitive-suffix workload (templated
  prose / code-completion shape): prompts end in a repeated pattern, so
  the n-gram prompt-lookup drafter can propose multiple tokens per step;
  the draft-model arm uses the target model as its own drafter (greedy
  self-drafting accepts every token — the structural upper bound).  The
  A/B reports decode steps, mean accepted tokens per slot-step and the
  acceptance rate; both speculative arms must emit **> 1 token per
  slot-step** (the CI smoke asserts this from the JSON).  CPU wall-clock
  is not the win here — fewer decode steps means fewer full KV-cache
  sweeps, which is the HBM-bound cost that dominates on real hardware.

* **open-loop SLO scheduling** (``--openloop``) — requests arrive on a
  Poisson process at a fixed offered QPS instead of being pre-loaded
  (closed-loop drains hide queueing delay entirely — the coordinated
  omission trap).  The mix is 3:1 low-priority long completions vs
  high-priority short interactive requests with a TTFT deadline, served
  from a deliberately tight block pool.  The A/B runs the same arrival
  trace under ``policy="slo"`` (priority/deadline ordering + preemption)
  and ``policy="fcfs"``; time is virtual (``ManualClock`` advanced by a
  per-step cost model), so TTFT/TPOT percentiles and preemption counts are
  exact and machine-independent.  The SLO arm must beat FCFS on
  high-priority p99 TTFT at equal offered load with >= 1 preemption
  recorded (asserted here and by the CI ``async-serving`` job from
  ``benchmarks/results/llm_inference_openloop.json``).  Deadline
  *enforcement* shows up as an A/B too: under FCFS the interactive
  requests that cannot make their TTFT deadline are aborted
  (``deadline_exceeded``) instead of served late; the SLO arm aborts none.

* **tiered KV cache** (``--spill``) — drop-on-evict vs host-RAM spill on a
  deliberately over-committed block pool: 8 tenants, each with a distinct
  3-block system prompt, return for a second round after their chains have
  been evicted.  The drop arm re-prefills the full prompt; the spill arm
  admits against the host tier and swaps the blocks back at a per-block
  restore cost (cheaper than recomputing the block's tokens, charged on the
  same virtual clock).  The spill arm must show a **strictly higher prefix
  hit rate and lower mean TTFT** with greedy output token-identical to the
  drop arm (asserted here and by the CI ``tiered-kv`` job from
  ``benchmarks/results/llm_inference_spill.json``).

* **multi-replica router** (``--router``) — N independent engines behind
  the prefix-affinity ``serving.router.Router``, driven closed-loop on
  virtual time where a fleet round costs the *slowest* replica's step
  (replicas run in parallel in real deployments).  Four arms over a
  multi-tenant workload (4 tenant families, each sharing a distinct
  3-block system prompt): 1 replica; 2 replicas with affinity routing
  (must scale aggregate tok/s > 1.3x and keep the prefix hit rate within
  10 points of single-replica); 2 replicas with random routing (the
  affinity arm must beat its hit rate — random placement splits tenant
  families across replicas and re-prefills the family prefix on each);
  and a chaos arm where a ``FaultPlan`` kills one replica mid-run — every
  in-flight request must fail over and finish with greedy output
  **token-identical** to the no-failure run, zero requests lost (asserted
  here and by the CI ``router-serving`` job from
  ``benchmarks/results/llm_inference_router.json``).

Results are also written to ``benchmarks/results/llm_inference.json`` (the
CI smoke step asserts the shared-prefix scenario parses and reports a
nonzero hit rate, and that the dense/paged rows carry TTFT/TPOT p50/p99
sourced from the engine's metrics registry).  ``--trace-out PATH``
additionally dumps the paged run's request-lifecycle Chrome trace (CI
validates its event schema; see docs/observability.md).  The full-size mistral-nemo-12b decode-step roofline
(HBM-bound KV reads) is derived from the dry-run artifacts when present.
"""

from __future__ import annotations

import json
import random
import time
from collections import deque
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine, ManualClock

RESULTS_DIR = Path(__file__).resolve().parent / "results"
RESULTS = RESULTS_DIR / "dryrun_single.json"

MAX_SEQ = 128
DENSE_BATCH = 4
BLOCK_SIZE = 16
N_REQUESTS = 16
MAX_NEW = 12

SYSTEM_PROMPT_LEN = 48  # 3 full blocks shared by every request
UNIQUE_TAIL = 4

SPEC_PATTERN = [17, 29, 11, 5]  # repetitive suffix the ngram drafter can look up
SPEC_REQUESTS = 8
SPEC_MAX_NEW = 24
SPEC_K = 4


def _drive(eng, prompts=None, *, max_new=MAX_NEW) -> dict:
    prompts = prompts or [[1 + i, 2, 3, 4] for i in range(N_REQUESTS)]
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=max_new, online=i % 2 == 0)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    s = eng.stats()
    s["wall_s"] = dt
    s["tok_per_s"] = s["tokens_out"] / dt
    # latency percentiles come from the engine's histogram layer, not ad-hoc
    # means over request timestamps
    for key, metric in (("ttft", "engine_ttft_seconds"), ("tpot", "engine_tpot_seconds")):
        p = eng.metrics.percentiles(metric, pcts=(50, 99))
        s[f"{key}_p50_s"], s[f"{key}_p99_s"] = p[50], p[99]
    return s


def _shared_prefix_prompts() -> list[list[int]]:
    # tail ids stay under the smoke vocab (256) — out-of-range ids hit the
    # embedding gather's clamp/garbage path and can poison logits with NaN
    system = [(7 * j + 3) % 199 + 2 for j in range(SYSTEM_PROMPT_LEN)]
    return [system + [190 + i * UNIQUE_TAIL + t for t in range(UNIQUE_TAIL)] for i in range(N_REQUESTS)]


def run(trace_out: str | None = None) -> list[dict]:
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    dense = InferenceEngine(
        cfg, params, max_batch=DENSE_BATCH, max_seq=MAX_SEQ, cache_kind="dense"
    )
    ds = _drive(dense)

    # paged engine at the dense byte budget: same number of cache positions
    # (null block included), sliced into blocks, slots decoupled from max_seq
    num_blocks = DENSE_BATCH * MAX_SEQ // BLOCK_SIZE
    paged = InferenceEngine(
        cfg,
        params,
        max_batch=N_REQUESTS,
        max_seq=MAX_SEQ,
        cache_kind="paged",
        block_size=BLOCK_SIZE,
        num_blocks=num_blocks,
        trace_capacity=65536,
    )
    ps = _drive(paged)
    if trace_out:
        Path(trace_out).parent.mkdir(parents=True, exist_ok=True)
        paged.tracer.write(trace_out)

    # shared-system-prompt A/B: same paged engine shape, prefix cache on/off.
    # max_batch < N so later requests admit after the prefix is indexed —
    # the steady-state of a service whose traffic outlives one batch.
    prompts = _shared_prefix_prompts()
    shared = {}
    for label, on in (("uncached", False), ("cached", True)):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=4,
            max_seq=MAX_SEQ,
            cache_kind="paged",
            block_size=BLOCK_SIZE,
            prefix_cache=on,
            prefill_budget=32,
        )
        shared[label] = _drive(eng, prompts, max_new=8)

    # speculative decode A/B on the repetitive-suffix workload: off vs the
    # ngram prompt-lookup drafter vs self-drafting (draft == target params,
    # the acceptance upper bound).  Same paged engine shape throughout.
    spec_prompts = [[200 + i] + SPEC_PATTERN * 6 for i in range(SPEC_REQUESTS)]
    spec = {}
    for label, kw in (
        ("off", {}),
        ("ngram", dict(spec_decode="ngram", spec_k=SPEC_K)),
        ("draft", dict(spec_decode="draft", spec_k=SPEC_K, draft_cfg=cfg, draft_params=params)),
    ):
        eng = InferenceEngine(
            cfg, params, max_batch=4, max_seq=MAX_SEQ, cache_kind="paged",
            block_size=BLOCK_SIZE, **kw,
        )
        spec[label] = _drive(eng, spec_prompts, max_new=SPEC_MAX_NEW)

    pct_fields = ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s")
    rows = [
        {
            "name": "llm_inference_dense_cpu",
            "us_per_call": ds["wall_s"] / max(ds["decode_steps"], 1) * 1e6,
            **{k: ds[k] for k in pct_fields},
            "derived": (
                f"tok/s={ds['tok_per_s']:.1f} peak_concurrent={ds['peak_active']} "
                f"cache_bytes={ds['cache_bytes']}"
            ),
        },
        {
            "name": "llm_inference_paged_cpu",
            "us_per_call": ps["wall_s"] / max(ps["decode_steps"], 1) * 1e6,
            **{k: ps[k] for k in pct_fields},
            "derived": (
                f"tok/s={ps['tok_per_s']:.1f} peak_concurrent={ps['peak_active']} "
                f"cache_bytes={ps['cache_bytes']} peak_blocks={ps['alloc_peak_in_use']}"
                f"/{ps['alloc_capacity']} "
                f"ttft_p50_ms={ps['ttft_p50_s'] * 1e3:.1f} "
                f"ttft_p99_ms={ps['ttft_p99_s'] * 1e3:.1f}"
            ),
        },
    ]
    for label in ("uncached", "cached"):
        s = shared[label]
        row = {
            "name": f"llm_inference_prefix_{label}_cpu",
            "us_per_call": (s["mean_ttft_s"] or 0.0) * 1e6,
            "prefill_tokens": s["prefill_tokens"],
            "prefix_hit_tokens": s.get("prefix_hit_tokens", 0),
            "prefix_hit_rate": s.get("prefix_hit_rate", 0.0),
            "mean_ttft_s": s["mean_ttft_s"],
            "ttft_p50_s": s["ttft_p50_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "derived": (
                f"mean_ttft_ms={(s['mean_ttft_s'] or 0.0) * 1e3:.1f} "
                f"prefill_tokens={s['prefill_tokens']} "
                f"hit_rate={s.get('prefix_hit_rate', 0.0):.2f}"
            ),
        }
        rows.append(row)
    for label in ("off", "ngram", "draft"):
        s = spec[label]
        rows.append(
            {
                "name": f"llm_inference_spec_{label}_cpu",
                "us_per_call": s["wall_s"] / max(s["decode_steps"], 1) * 1e6,
                "decode_steps": s["decode_steps"],
                "tokens_out": s["tokens_out"],
                "accepted_per_step": s.get("accepted_per_step", 1.0),
                "acceptance_rate": s.get("acceptance_rate", 0.0),
                "derived": (
                    f"steps={s['decode_steps']} tok={s['tokens_out']} "
                    f"accepted_per_step={s.get('accepted_per_step', 1.0):.2f} "
                    f"acceptance_rate={s.get('acceptance_rate', 0.0):.2f}"
                ),
            }
        )
    assert ps["cache_bytes"] <= ds["cache_bytes"], "paged budget drifted above dense"
    for label in ("ngram", "draft"):
        assert spec[label]["accepted_per_step"] > 1.0, (
            f"speculative ({label}) must emit > 1 token per slot-step on the "
            f"repetitive-suffix workload: {spec[label]['accepted_per_step']:.2f}"
        )
        assert spec[label]["decode_steps"] < spec["off"]["decode_steps"], (
            f"speculative ({label}) must take fewer decode steps than baseline"
        )
    cached, uncached = shared["cached"], shared["uncached"]
    assert cached["prefill_tokens"] * 2 <= uncached["prefill_tokens"], (
        f"prefix cache must save >= 2x prefill compute on the shared-prompt mix: "
        f"{cached['prefill_tokens']} vs {uncached['prefill_tokens']}"
    )
    assert cached["prefix_hit_rate"] > 0, "shared-prefix workload produced no hits"
    # derived decode-step time for the full 12B model from the dry-run
    if RESULTS.exists():
        rec = json.loads(RESULTS.read_text()).get("mistral-nemo-12b|decode_32k")
        if rec and rec.get("status") == "run":
            bound = max(rec["roofline"].values())
            rows.append(
                {
                    "name": "llm_inference_12b_decode32k_roofline",
                    "us_per_call": bound * 1e6,
                    "derived": f"batch128 -> {128/bound:.0f} tok/s/pod, dominant={rec['dominant']}",
                }
            )
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference.json").write_text(json.dumps(rows, indent=1))
    return rows


# ---- open-loop SLO scheduling A/B -----------------------------------------
OPENLOOP_QPS = 6.0
OPENLOOP_REQUESTS = 24  # every 4th is high-priority interactive
OPENLOOP_SEED = 7
LOW_PROMPT, LOW_MAX_NEW = 24, 20  # 3 blocks of 16 at worst case
HI_PROMPT, HI_MAX_NEW = 6, 6  # 1 block
HI_DEADLINE_S = 0.25  # TTFT target for the interactive class
# virtual per-step cost model: fixed dispatch overhead + per-token compute
# (prefill chunk tokens, decode tokens and verify windows all count)
STEP_OVERHEAD_S = 0.020
TOKEN_COST_S = 0.001


def _openloop_arrivals() -> list[tuple[float, bool]]:
    """One Poisson arrival trace shared by both policy arms: (time, is_hi)."""
    rng = random.Random(OPENLOOP_SEED)
    t, out = 0.0, []
    for i in range(OPENLOOP_REQUESTS):
        t += rng.expovariate(OPENLOOP_QPS)
        out.append((t, i % 4 == 3))
    return out


def _drive_openloop(eng, clock: ManualClock, arrivals) -> dict:
    """Submit on the arrival trace and step on virtual time.

    The clock advances by the step cost model after every ``step()`` and
    jumps to the next arrival when the engine idles, so queueing delay —
    the thing closed-loop drains cannot see — lands in every TTFT."""
    pending = deque(arrivals)
    rng = random.Random(OPENLOOP_SEED + 1)
    reqs = []
    while pending or eng.has_work:
        while pending and pending[0][0] <= clock.now:
            _, is_hi = pending.popleft()
            n = HI_PROMPT if is_hi else LOW_PROMPT
            prompt = [rng.randrange(2, 200) for _ in range(n)]
            reqs.append(
                eng.submit(
                    prompt,
                    max_new_tokens=HI_MAX_NEW if is_hi else LOW_MAX_NEW,
                    priority=2 if is_hi else 0,
                    deadline_s=HI_DEADLINE_S if is_hi else None,
                )
            )
        if not eng.has_work:
            clock.advance(max(pending[0][0] - clock.now, 0.0))
            continue
        # dispatch overhead lands before the step so a first token emitted
        # inside it carries a non-zero TTFT; per-token compute lands after
        clock.advance(STEP_OVERHEAD_S)
        fed0 = eng.prefill_tokens + eng.verify_tokens
        produced = eng.step()
        fed = eng.prefill_tokens + eng.verify_tokens - fed0
        clock.advance(TOKEN_COST_S * (produced + fed))
    s = eng.stats()
    s["makespan_s"] = clock.now
    s["qps_sustained"] = len(reqs) / clock.now
    for key, metric in (("ttft", "engine_ttft_seconds"), ("tpot", "engine_tpot_seconds")):
        p = eng.metrics.percentiles(metric, pcts=(50, 99))
        s[f"{key}_p50_s"], s[f"{key}_p99_s"] = p[50], p[99]
    hi_ttfts = [r.ttft for r in reqs if r.priority > 0 and r.ttft is not None]
    s["high_priority_ttft_p99_s"] = float(np.percentile(hi_ttfts, 99))
    s["high_priority_ttft_p50_s"] = float(np.percentile(hi_ttfts, 50))
    return s


def run_openloop() -> list[dict]:
    """SLO vs FCFS on one Poisson arrival trace at equal offered QPS."""
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    arrivals = _openloop_arrivals()
    rows = []
    by_policy = {}
    for policy in ("slo", "fcfs"):
        clock = ManualClock()
        # pool sized so four low-priority completions exhaust it: the
        # interactive class can only meet its deadline by preempting
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=4,
            max_seq=MAX_SEQ,
            cache_kind="paged",
            block_size=BLOCK_SIZE,
            num_blocks=13,
            prefix_cache=True,
            prefill_budget=16,
            policy=policy,
            clock=clock,
        )
        s = _drive_openloop(eng, clock, arrivals)
        by_policy[policy] = s
        rows.append(
            {
                "name": f"llm_inference_openloop_{policy}_cpu",
                "policy": policy,
                "qps_offered": OPENLOOP_QPS,
                "qps_sustained": s["qps_sustained"],
                "us_per_call": s["high_priority_ttft_p99_s"] * 1e6,
                "ttft_p50_s": s["ttft_p50_s"],
                "ttft_p99_s": s["ttft_p99_s"],
                "tpot_p50_s": s["tpot_p50_s"],
                "tpot_p99_s": s["tpot_p99_s"],
                "high_priority_ttft_p50_s": s["high_priority_ttft_p50_s"],
                "high_priority_ttft_p99_s": s["high_priority_ttft_p99_s"],
                "preemptions": s["preemptions"],
                "requests_preempted": s["requests_preempted"],
                "deadline_violations": s["deadline_violations"],
                "requests_aborted": s["requests_aborted"],
                "requests_done": s["requests_done"],
                "derived": (
                    f"hi_p99_ttft_ms={s['high_priority_ttft_p99_s'] * 1e3:.1f} "
                    f"preemptions={s['preemptions']} "
                    f"deadline_miss={s['deadline_violations']} "
                    f"aborted={s['requests_aborted']} "
                    f"qps={s['qps_sustained']:.2f}"
                ),
            }
        )
    slo, fcfs = by_policy["slo"], by_policy["fcfs"]
    assert slo["requests_done"] == fcfs["requests_done"] == OPENLOOP_REQUESTS
    assert slo["preemptions"] >= 1, "tight pool must force at least one preemption"
    assert fcfs["preemptions"] == 0, "fcfs must never preempt"
    assert slo["high_priority_ttft_p99_s"] < fcfs["high_priority_ttft_p99_s"], (
        f"SLO scheduling must beat FCFS on high-priority p99 TTFT at equal "
        f"offered QPS: {slo['high_priority_ttft_p99_s']:.3f}s vs "
        f"{fcfs['high_priority_ttft_p99_s']:.3f}s"
    )
    assert slo["deadline_violations"] <= fcfs["deadline_violations"]
    # deadline *enforcement*: FCFS requests that cannot make their TTFT
    # deadline are shed (deadline_exceeded abort) instead of served late;
    # SLO preemption keeps every interactive request inside its deadline
    assert slo["requests_aborted"] == 0, "SLO arm must serve every request in time"
    assert fcfs["requests_aborted"] >= 1, "FCFS must shed hopeless deadline requests"
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference_openloop.json").write_text(json.dumps(rows, indent=1))
    return rows


# ---- tiered KV cache: drop-on-evict vs host-RAM spill ----------------------
SPILL_GROUPS = 8
SPILL_ROUNDS = 2
SPILL_MAX_NEW = 8
SPILL_NUM_BLOCKS = 12  # 11 usable: ~1.5 requests' working set, constant eviction
SPILL_BYTES = 64 << 20
# restoring one spilled block (H2D copy of block_size rows) is cheaper than
# recomputing its 16 tokens (16 * TOKEN_COST_S = 16 ms) but not free
RESTORE_COST_S = 0.004


def _spill_prompts() -> list[list[int]]:
    """SPILL_GROUPS tenants, each with a distinct 48-token system prompt
    (3 full blocks) plus a 4-token unique tail — together they need ~3x the
    pool, so every chain is evicted before its tenant returns."""
    prompts = []
    for g in range(SPILL_GROUPS):
        system = [(11 * g + 3 * j + 5) % 193 + 2 for j in range(SYSTEM_PROMPT_LEN)]
        prompts.append(system + [198 + g * UNIQUE_TAIL + k for k in range(UNIQUE_TAIL)])
    return prompts


def _drive_spill(eng, clock: ManualClock) -> tuple[dict, list]:
    """Sequential submit+drain per request on virtual time, SPILL_ROUNDS
    passes over the tenant mix: round 2 finds round 1's chains evicted —
    re-prefilled (drop tier) or swapped back from host RAM (spill tier).
    Step cost = dispatch overhead + per-token compute + per-block restore."""
    toks, ttfts = [], []
    for _ in range(SPILL_ROUNDS):
        for p in _spill_prompts():
            r = eng.submit(list(p), max_new_tokens=SPILL_MAX_NEW)
            while eng.has_work:
                clock.advance(STEP_OVERHEAD_S)
                fed0 = eng.prefill_tokens + eng.verify_tokens
                restored0 = eng.restores
                produced = eng.step()
                fed = eng.prefill_tokens + eng.verify_tokens - fed0
                clock.advance(
                    TOKEN_COST_S * (produced + fed)
                    + RESTORE_COST_S * (eng.restores - restored0)
                )
            toks.append(list(r.generated))
            ttfts.append(r.ttft)
    s = eng.stats()
    s["mean_ttft_s"] = float(np.mean(ttfts))
    return s, toks


def run_spill() -> list[dict]:
    """Tiered-KV A/B: drop-on-evict vs host-RAM spill on an over-committed
    pool.  Same engine shape, same tenant mix, same virtual cost model; the
    spill arm must win hit rate and mean TTFT with token-identical output."""
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    arms, toks = {}, {}
    for label, spill_bytes in (("drop", 0), ("spill", SPILL_BYTES)):
        clock = ManualClock()
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=2,
            max_seq=MAX_SEQ,
            cache_kind="paged",
            block_size=BLOCK_SIZE,
            num_blocks=SPILL_NUM_BLOCKS,
            prefix_cache=True,
            prefill_budget=16,
            spill_bytes=spill_bytes,
            clock=clock,
        )
        arms[label], toks[label] = _drive_spill(eng, clock)
    drop, spill = arms["drop"], arms["spill"]
    assert toks["spill"] == toks["drop"], "spill tier changed greedy outputs"
    assert drop["alloc_evictions_dropped"] > 0, "pool never overflowed; no A/B"
    assert spill["alloc_evictions_spilled"] > 0 and spill["restores"] > 0
    assert spill["prefix_hit_rate"] > drop["prefix_hit_rate"], (
        f"spill tier must lift the hit rate on the returning-tenant mix: "
        f"{spill['prefix_hit_rate']:.2f} vs {drop['prefix_hit_rate']:.2f}"
    )
    assert spill["mean_ttft_s"] < drop["mean_ttft_s"], (
        f"restoring from host RAM must beat re-prefill on mean TTFT: "
        f"{spill['mean_ttft_s']:.3f}s vs {drop['mean_ttft_s']:.3f}s"
    )
    assert spill["prefill_tokens"] < drop["prefill_tokens"]
    rows = []
    for label in ("drop", "spill"):
        s = arms[label]
        rows.append(
            {
                "name": f"llm_inference_tiered_{label}_cpu",
                "us_per_call": s["mean_ttft_s"] * 1e6,
                "mean_ttft_s": s["mean_ttft_s"],
                "prefill_tokens": s["prefill_tokens"],
                "prefix_hit_rate": s.get("prefix_hit_rate", 0.0),
                "evictions_dropped": s["alloc_evictions_dropped"],
                "evictions_spilled": s["alloc_evictions_spilled"],
                "spills": s.get("spill_spills", 0),
                "restores": s.get("restores", 0),
                "spill_drops": s.get("spill_drops", 0),
                "spill_hit_tokens": s.get("spill_hit_tokens", 0),
                "tokens_match": toks[label] == toks["drop"],
                "derived": (
                    f"mean_ttft_ms={s['mean_ttft_s'] * 1e3:.1f} "
                    f"hit_rate={s.get('prefix_hit_rate', 0.0):.2f} "
                    f"prefill_tokens={s['prefill_tokens']} "
                    f"restores={s.get('restores', 0)}"
                ),
            }
        )
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference_spill.json").write_text(json.dumps(rows, indent=1))
    return rows


# ---- multi-replica router: affinity, scaling, failover --------------------
ROUTER_TENANTS = 8
ROUTER_PER_TENANT = 2
ROUTER_MAX_NEW = 12


def _tenant_prompts() -> list[list[int]]:
    """ROUTER_TENANTS families, each sharing a distinct 48-token system
    prompt (3 full blocks), interleaved in submission order — the
    multi-tenant shape where placement matters: affinity keeps a family on
    one replica (its prefix blocks are hot there), random placement splits
    it and pays the family prefill on every replica it lands on."""
    prompts = []
    for i in range(ROUTER_PER_TENANT):
        for t in range(ROUTER_TENANTS):
            system = [(13 * t + 5 * j + 7) % 197 + 2 for j in range(SYSTEM_PROMPT_LEN)]
            # tails stay under the smoke vocab (256): 4 unique ids per request
            tail = [192 + (t * ROUTER_PER_TENANT + i) * UNIQUE_TAIL + k for k in range(UNIQUE_TAIL)]
            prompts.append(system + tail)
    return prompts


def _make_router(cfg, params, n, *, clock, policy="affinity", fault_plans=None):
    from repro.serving import Replica, Router

    replicas = []
    for i in range(n):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=4,
            max_seq=MAX_SEQ,
            cache_kind="paged",
            block_size=BLOCK_SIZE,
            prefix_cache=True,
            prefill_budget=16,
            clock=clock,
        )
        replicas.append(Replica(i, eng, clock=clock, fault_plan=(fault_plans or {}).get(i)))
    return Router(replicas, policy=policy, clock=clock)


def _replica_work(eng) -> int:
    return eng.prefill_tokens + eng.verify_tokens + eng.tokens_out


def _drive_router(router, clock: ManualClock, prompts) -> tuple[dict, list]:
    """Closed-loop fleet drain on virtual time.

    Replicas execute in parallel in a real deployment, so one fleet round
    costs the *slowest* replica's step: fixed dispatch overhead plus the
    per-token cost of the largest per-replica work delta that round."""
    reqs = [router.submit(list(p), max_new_tokens=ROUTER_MAX_NEW) for p in prompts]
    while router.has_work:
        before = {rep.id: _replica_work(rep.engine) for rep in router.replicas}
        router.step()
        deltas = [_replica_work(rep.engine) - before[rep.id] for rep in router.replicas]
        clock.advance(STEP_OVERHEAD_S + TOKEN_COST_S * max(deltas, default=0))
    s = router.stats()
    s["makespan_s"] = clock.now
    s["tok_per_s"] = s["tokens_out"] / clock.now if clock.now else 0.0
    return s, [list(r.generated) for r in reqs]


def run_router() -> list[dict]:
    """Router A/B: scaling, affinity-vs-random hit rate, mid-run kill."""
    from repro.serving import FaultPlan

    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = _tenant_prompts()
    n_req = len(prompts)

    def arm(n, policy="affinity", fault_plans=None):
        clock = ManualClock()
        router = _make_router(cfg, params, n, clock=clock, policy=policy, fault_plans=fault_plans)
        s, toks = _drive_router(router, clock, prompts)
        return router, s, toks

    single_router, single, single_toks = arm(1)
    aff_router, aff, aff_toks = arm(2)
    _, rnd, rnd_toks = arm(2, policy="random")
    # kill replica 0 halfway through the steps it executed in the healthy
    # affinity run: requests are mid-flight there when it dies
    crash_at = max(aff_router.replicas[0].steps // 2, 1)
    _, fo, fo_toks = arm(2, fault_plans={0: FaultPlan(crash_at_step=crash_at)})

    assert aff_toks == single_toks and rnd_toks == single_toks, (
        "replica placement changed greedy outputs"
    )
    assert fo["requests_done"] == n_req and fo["requests_failed"] == 0, (
        f"lost requests after replica kill: done={fo['requests_done']}/{n_req} "
        f"failed={fo['requests_failed']}"
    )
    assert fo["failovers"] >= 1, "the kill must have forced at least one failover"
    assert fo["replica_states"][0] == "dead"
    assert fo_toks == single_toks, "failover changed greedy outputs vs no-failure run"
    assert aff["tok_per_s"] > 1.3 * single["tok_per_s"], (
        f"2 replicas must scale aggregate decode: {aff['tok_per_s']:.1f} vs "
        f"{single['tok_per_s']:.1f} tok/s"
    )
    assert aff["prefix_hit_rate"] >= single["prefix_hit_rate"] - 0.10, (
        f"affinity routing lost the prefix cache: hit rate "
        f"{aff['prefix_hit_rate']:.2f} vs {single['prefix_hit_rate']:.2f} on 1 replica"
    )
    assert aff["prefix_hit_rate"] > rnd["prefix_hit_rate"], (
        f"affinity must beat random placement on hit rate: "
        f"{aff['prefix_hit_rate']:.2f} vs {rnd['prefix_hit_rate']:.2f}"
    )

    rows = []
    for name, s, toks in (
        ("router_single", single, single_toks),
        ("router_affinity", aff, aff_toks),
        ("router_random", rnd, rnd_toks),
        ("router_failover", fo, fo_toks),
    ):
        rows.append(
            {
                "name": f"llm_inference_{name}_cpu",
                "us_per_call": s["makespan_s"] / max(s["requests_done"], 1) * 1e6,
                "replicas": s["replicas"],
                "policy": s["routing_policy"],
                "tok_per_s": s["tok_per_s"],
                "makespan_s": s["makespan_s"],
                "tokens_out": s["tokens_out"],
                "prefix_hit_rate": s["prefix_hit_rate"],
                "requests_done": s["requests_done"],
                "requests_failed": s["requests_failed"],
                "failovers": s["failovers"],
                "retries": s["retries"],
                "replica_states": s["replica_states"],
                "tokens_match_single": toks == single_toks,
                "derived": (
                    f"tok/s={s['tok_per_s']:.1f} hit={s['prefix_hit_rate']:.2f} "
                    f"failovers={s['failovers']:.0f} done={s['requests_done']}/{n_req}"
                ),
            }
        )
    rows[1]["speedup_vs_single"] = aff["tok_per_s"] / single["tok_per_s"]
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference_router.json").write_text(json.dumps(rows, indent=1))
    return rows


# ---- fused one-dispatch step A/B ------------------------------------------
# per-host-round-trip overhead for the modeled step latency: kernel-launch +
# host sync cost on an HBM-class accelerator (the quantity the fused step
# removes; a 64-dim CPU smoke model cannot surface it in wall-clock, same
# reasoning as the openloop arm's virtual clock)
DISPATCH_OVERHEAD_S = 0.002


def run_fused() -> list[dict]:
    """Legacy multi-dispatch engine vs the fused one-dispatch step.

    Same shared-system-prompt workload (chunked prefill + prefix cache, so
    mixed chunk/decode ticks occur), both arms warmed on an identical round
    so every (rows, width) graph shape is compiled before timing.  Asserts:
    greedy output token-identical, strictly fewer dispatches and host syncs
    per decoded token, and lower mean per-step latency under the dispatch
    cost model (overhead x dispatches + token compute)."""
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = _shared_prefix_prompts()
    arms = {}
    outs = {}
    for label, fused in (("legacy", False), ("fused", True)):
        eng = InferenceEngine(
            cfg, params, max_batch=4, max_seq=MAX_SEQ, cache_kind="paged",
            block_size=BLOCK_SIZE, prefix_cache=True, prefill_budget=32,
            fused=fused,
        )
        for p in prompts:  # warm-up round: compiles every graph shape
            eng.submit(p, max_new_tokens=8)
        eng.run_until_drained()
        d0, y0, s0 = eng.dispatches_total, eng.host_syncs_total, eng.steps
        w0 = eng.prefill_tokens + eng.verify_tokens
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        t0 = time.perf_counter()
        eng.run_until_drained()
        wall = time.perf_counter() - t0
        steps = eng.steps - s0
        toks = sum(len(r.generated) for r in reqs)
        fed = eng.prefill_tokens + eng.verify_tokens - w0
        disp = eng.dispatches_total - d0
        syncs = eng.host_syncs_total - y0
        outs[label] = [list(r.generated) for r in reqs]
        arms[label] = {
            "wall_step_s": wall / max(steps, 1),
            "model_step_s": (
                DISPATCH_OVERHEAD_S * disp + TOKEN_COST_S * (toks + fed)
            ) / max(steps, 1),
            "dispatches_per_token": disp / max(toks, 1),
            "host_syncs_per_token": syncs / max(toks, 1),
            "dispatches_per_step": disp / max(steps, 1),
            "decode_steps": steps,
            "tokens_out": toks,
        }
    assert outs["fused"] == outs["legacy"], "fused step changed greedy tokens"
    fs, ls = arms["fused"], arms["legacy"]
    assert fs["dispatches_per_token"] < ls["dispatches_per_token"], (
        f"fused must dispatch less per decoded token: "
        f"{fs['dispatches_per_token']:.3f} vs {ls['dispatches_per_token']:.3f}"
    )
    assert fs["host_syncs_per_token"] <= ls["host_syncs_per_token"]
    assert fs["model_step_s"] < ls["model_step_s"], (
        f"fused must lower modeled per-step latency: "
        f"{fs['model_step_s']:.4f} vs {ls['model_step_s']:.4f}"
    )
    rows = []
    for label in ("legacy", "fused"):
        a = arms[label]
        rows.append(
            {
                "name": f"llm_inference_{label}_step_cpu",
                "us_per_call": a["wall_step_s"] * 1e6,
                "model_step_s": a["model_step_s"],
                "dispatches_per_token": a["dispatches_per_token"],
                "host_syncs_per_token": a["host_syncs_per_token"],
                "dispatches_per_step": a["dispatches_per_step"],
                "decode_steps": a["decode_steps"],
                "tokens_out": a["tokens_out"],
                "derived": (
                    f"model_step_ms={a['model_step_s'] * 1e3:.2f} "
                    f"disp/tok={a['dispatches_per_token']:.3f} "
                    f"syncs/tok={a['host_syncs_per_token']:.3f}"
                ),
            }
        )
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference_fused.json").write_text(json.dumps(rows, indent=1))
    return rows


def run_tp(tp: int) -> list[dict]:
    """TP=tp vs TP=1 A/B: token-identical greedy output, sharded cache bytes."""
    from repro.launch.mesh import make_serving_mesh

    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = _shared_prefix_prompts()[:8]

    def drive(mesh):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=4,
            max_seq=MAX_SEQ,
            cache_kind="paged",
            block_size=BLOCK_SIZE,
            prefix_cache=True,
            prefill_budget=32,
            spec_decode="ngram",
            spec_k=SPEC_K,
            mesh=mesh,
        )
        t0 = time.perf_counter()
        reqs = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
        eng.run_until_drained()
        s = eng.stats()
        s["wall_s"] = time.perf_counter() - t0
        return [r.generated for r in reqs], s, eng

    base_toks, base_stats, _ = drive(None)
    tp_toks, tp_stats, eng = drive(make_serving_mesh(tp))
    assert tp_toks == base_toks, f"TP={tp} changed greedy tokens vs TP=1"
    assert tp_stats["cache_bytes"] == base_stats["cache_bytes"], "global bytes drifted"
    kv_spec = str(eng.cache["k"].sharding.spec)
    rows = [
        {
            "name": f"llm_inference_tp{n}_cpu",
            "us_per_call": s["wall_s"] / max(s["decode_steps"], 1) * 1e6,
            "tp": n,
            "tokens_equal": True,
            "tokens_out": s["tokens_out"],
            "cache_bytes": s["cache_bytes"],
            "cache_bytes_per_device": s.get("cache_bytes_per_device", s["cache_bytes"]),
            "prefix_hit_rate": s.get("prefix_hit_rate", 0.0),
            "accepted_per_step": s.get("accepted_per_step", 1.0),
            "derived": (
                f"tok={s['tokens_out']} cache_bytes={s['cache_bytes']} "
                f"per_device={s.get('cache_bytes_per_device', s['cache_bytes'])}"
            ),
        }
        for n, s in ((1, base_stats), (tp, tp_stats))
    ]
    rows[1]["kv_pool_spec"] = kv_spec
    RESULTS_DIR.mkdir(exist_ok=True)
    (RESULTS_DIR / "llm_inference_tp.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tp", type=int, default=1,
        help="run the tensor-parallel token-equivalence A/B at this degree "
        "instead of the single-device scenarios",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write the paged-engine run's request-lifecycle trace as "
        "Chrome-trace JSON (single-device scenarios only)",
    )
    ap.add_argument(
        "--openloop", action="store_true",
        help="run the open-loop Poisson-arrival SLO-vs-FCFS A/B on virtual "
        "time instead of the closed-loop drain scenarios",
    )
    ap.add_argument(
        "--router", action="store_true",
        help="run the multi-replica router A/B (scaling, affinity-vs-random "
        "prefix hit rate, mid-run replica kill with failover) on virtual time",
    )
    ap.add_argument(
        "--spill", action="store_true",
        help="run the tiered-KV A/B (drop-on-evict vs host-RAM spill on an "
        "over-committed pool) on virtual time",
    )
    ap.add_argument(
        "--fused", action="store_true",
        help="run the fused one-dispatch step A/B (legacy multi-dispatch vs "
        "unified row-batch engine): token equivalence, dispatches per token, "
        "modeled per-step latency",
    )
    args = ap.parse_args()
    if args.fused:
        rows = run_fused()
    elif args.spill:
        rows = run_spill()
    elif args.router:
        rows = run_router()
    elif args.openloop:
        rows = run_openloop()
    else:
        rows = run_tp(args.tp) if args.tp > 1 else run(trace_out=args.trace_out)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
