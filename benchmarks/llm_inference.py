"""llama.cpp-style LLM inference (paper Fig. 9), paged vs dense engines.

The paper reports 70B llama.cpp decode throughput on the Grace CPU.  This
harness serves a reduced model through the continuous-batching engine —
once with the slot-granular dense cache and once with the paged block-pool
cache at the **same cache-byte budget** — and reports decode tokens/s,
blocks in use, and the achievable concurrent requests under each layout.
The full-size mistral-nemo-12b decode-step roofline (HBM-bound KV reads) is
derived from the dry-run artifacts when present.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun_single.json"

MAX_SEQ = 128
DENSE_BATCH = 4
BLOCK_SIZE = 16
N_REQUESTS = 16
MAX_NEW = 12


def _drive(eng) -> dict:
    for i in range(N_REQUESTS):
        eng.submit([1 + i, 2, 3, 4], max_new_tokens=MAX_NEW, online=i % 2 == 0)
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    s = eng.stats()
    s["wall_s"] = dt
    s["tok_per_s"] = s["tokens_out"] / dt
    return s


def run() -> list[dict]:
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    dense = InferenceEngine(
        cfg, params, max_batch=DENSE_BATCH, max_seq=MAX_SEQ, cache_kind="dense"
    )
    ds = _drive(dense)

    # paged engine at the dense byte budget: same number of cache positions
    # (null block included), sliced into blocks, slots decoupled from max_seq
    num_blocks = DENSE_BATCH * MAX_SEQ // BLOCK_SIZE
    paged = InferenceEngine(
        cfg,
        params,
        max_batch=N_REQUESTS,
        max_seq=MAX_SEQ,
        cache_kind="paged",
        block_size=BLOCK_SIZE,
        num_blocks=num_blocks,
    )
    ps = _drive(paged)

    rows = [
        {
            "name": "llm_inference_dense_cpu",
            "us_per_call": ds["wall_s"] / max(ds["decode_steps"], 1) * 1e6,
            "derived": (
                f"tok/s={ds['tok_per_s']:.1f} peak_concurrent={ds['peak_active']} "
                f"cache_bytes={ds['cache_bytes']}"
            ),
        },
        {
            "name": "llm_inference_paged_cpu",
            "us_per_call": ps["wall_s"] / max(ps["decode_steps"], 1) * 1e6,
            "derived": (
                f"tok/s={ps['tok_per_s']:.1f} peak_concurrent={ps['peak_active']} "
                f"cache_bytes={ps['cache_bytes']} peak_blocks={ps['alloc_peak_in_use']}"
                f"/{ps['alloc_capacity']}"
            ),
        },
    ]
    assert ps["cache_bytes"] <= ds["cache_bytes"], "paged budget drifted above dense"
    # derived decode-step time for the full 12B model from the dry-run
    if RESULTS.exists():
        rec = json.loads(RESULTS.read_text()).get("mistral-nemo-12b|decode_32k")
        if rec and rec.get("status") == "run":
            bound = max(rec["roofline"].values())
            rows.append(
                {
                    "name": "llm_inference_12b_decode32k_roofline",
                    "us_per_call": bound * 1e6,
                    "derived": f"batch128 -> {128/bound:.0f} tok/s/pod, dominant={rec['dominant']}",
                }
            )
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
