"""BabelStream (paper Fig. 10): memory-bandwidth microbenchmark.

The paper runs BabelStream across nine programming models on GH200 and
reports fractions of peak HBM bandwidth.  This harness runs the Pallas
kernels (interpret mode on CPU — wall-clock is NOT the metric off-TPU) and
reports the roofline-derived figures: bytes moved per kernel and, on TPU,
achieved GB/s vs the 819 GB/s v5e peak.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import (
    stream_add,
    stream_bytes,
    stream_copy,
    stream_dot,
    stream_mul,
    stream_triad,
)
from repro.launch.hlo_analysis import HBM_BW

N = 2**20  # elements (scaled for CPU interpret mode; 2**27 on real TPU)


def run(n: int = N, dtype=jnp.float32, iters: int = 3) -> list[dict]:
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n,), dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    c = jax.random.normal(jax.random.fold_in(key, 2), (n,), dtype)
    item = jnp.dtype(dtype).itemsize
    kernels = {
        "copy": lambda: stream_copy(a),
        "mul": lambda: stream_mul(c),
        "add": lambda: stream_add(a, b),
        "triad": lambda: stream_triad(b, c),
        "dot": lambda: stream_dot(a, b),
    }
    rows = []
    on_tpu = jax.default_backend() == "tpu"
    for name, fn in kernels.items():
        fn()  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / iters
        nbytes = stream_bytes(name, n, item)
        rows.append(
            {
                "name": f"babelstream_{name}",
                "us_per_call": dt * 1e6,
                "bytes": nbytes,
                "modeled_tpu_us": nbytes / HBM_BW * 1e6,  # at 819 GB/s
                "achieved_gbps": nbytes / dt / 1e9 if on_tpu else None,
            }
        )
    return rows


def main() -> None:
    for r in run():
        derived = f"modeled_v5e_us={r['modeled_tpu_us']:.1f}"
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}")


if __name__ == "__main__":
    main()
